"""Fused gather + distance Pallas kernel — the ANNS hot path.

The lazy load phase (Algorithm 1 line 24–27) materializes the miss list
``L``, bulk-loads those vectors, and computes their distances to the
query. On TPU the gather and the distance fuse into one kernel using the
scalar-prefetch idiom (the same indirection pattern as paged attention):
the id list sits in SMEM ahead of the grid; each grid step's BlockSpec
``index_map`` reads ``ids[i]`` to select which table row-block to DMA from
HBM into VMEM, and the kernel body computes the distance contribution —
the gathered row never round-trips to HBM.

Rows are processed in groups of ``rg`` (default 8) so each DMA moves
``rg × d × 4`` bytes; ids within a group are arbitrary (one row-block DMA
each via a second grid dimension).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gd_kernel(ids_ref, q_ref, row_ref, o_ref, *, metric: str):
    """Grid = (n_ids,). row_ref holds table[ids[i]] (1, d) via index_map."""
    i = pl.program_id(0)
    x = row_ref[...].astype(jnp.float32)  # (1, d)
    q = q_ref[...].astype(jnp.float32)  # (1, d)
    if metric == "l2":
        diff = x - q
        d = jnp.sum(diff * diff)
    else:  # 'ip' ('cos' pre-normalized by wrapper)
        d = -jnp.sum(x * q)
    valid = ids_ref[i] >= 0
    o_ref[0] = jnp.where(valid, d, jnp.inf)


@functools.partial(
    jax.jit, static_argnames=("metric", "interpret")
)
def gather_distance_pallas(
    table: jnp.ndarray,  # (N, d) — stays in HBM; rows DMA'd on demand
    ids: jnp.ndarray,  # (B,) int32, -1 padded
    q: jnp.ndarray,  # (d,)
    metric: str = "l2",
    interpret: bool = True,
) -> jnp.ndarray:
    """Distances (B,) of table[ids] to q; +inf for padded ids."""
    N, d = table.shape
    B = ids.shape[0]
    if metric == "cos":
        table = table / (jnp.linalg.norm(table, axis=-1, keepdims=True) + 1e-30)
        q = q / (jnp.linalg.norm(q) + 1e-30)
        metric = "ip"
    raw_ids = ids.astype(jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, ids_ref: (0, 0)),  # q (broadcast)
            # raw ids prefetched; clip in the index_map so the DMA stays
            # in-bounds while the kernel body can test validity (id >= 0).
            pl.BlockSpec(
                (1, d), lambda i, ids_ref: (jnp.maximum(ids_ref[i], 0), 0)
            ),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, ids_ref: (i,)),
    )
    out = pl.pallas_call(
        functools.partial(_gd_kernel, metric=metric),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )(raw_ids, q[None, :], table)
    return jnp.where(ids >= 0, out, jnp.inf)


# ----------------------------------------------------------- batched form


def _gd_batch_kernel(ids_ref, q_ref, row_ref, o_ref, *, metric: str):
    """Grid = (B, K). row_ref holds table[ids[b, i]] (1, d); q_ref holds
    Q[b] (1, d) — both selected by their index_maps."""
    b = pl.program_id(0)
    i = pl.program_id(1)
    x = row_ref[...].astype(jnp.float32)  # (1, d)
    q = q_ref[...].astype(jnp.float32)  # (1, d)
    if metric == "l2":
        diff = x - q
        d = jnp.sum(diff * diff)
    else:  # 'ip' ('cos' pre-normalized by wrapper)
        d = -jnp.sum(x * q)
    valid = ids_ref[b, i] >= 0
    o_ref[0, 0] = jnp.where(valid, d, jnp.inf)


@functools.partial(
    jax.jit, static_argnames=("metric", "interpret")
)
def gather_distance_batch_pallas(
    table: jnp.ndarray,  # (N, d) — stays in HBM; rows DMA'd on demand
    ids: jnp.ndarray,  # (B, K) int32, -1 padded — per-query miss lists
    Q: jnp.ndarray,  # (B, d) — one query per id row
    metric: str = "l2",
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched fused gather + distance: (B, K) ids × (B, d) queries →
    (B, K) distances, +inf for padded ids.

    The TPU-native compute path for the batched load phase's distance
    work (DESIGN.md §5), dispatched via ``ops.gather_distance_batch``
    (the host-driven engine computes load-phase distances from the
    already-fetched vectors instead): the (B, K) id matrix is
    scalar-prefetched, the grid walks (query, slot), and each step DMAs
    exactly one table row — the same indirection as the single-query
    kernel with the query block also selected per grid row, so nothing
    is materialized at (B, K, d).
    """
    N, d = table.shape
    B, K = ids.shape
    if metric == "cos":
        table = table / (jnp.linalg.norm(table, axis=-1, keepdims=True) + 1e-30)
        Q = Q / (jnp.linalg.norm(Q, axis=-1, keepdims=True) + 1e-30)
        metric = "ip"
    raw_ids = ids.astype(jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, i, ids_ref: (b, 0)),  # Q[b]
            pl.BlockSpec(
                (1, d),
                lambda b, i, ids_ref: (jnp.maximum(ids_ref[b, i], 0), 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, i, ids_ref: (b, i)),
    )
    out = pl.pallas_call(
        functools.partial(_gd_batch_kernel, metric=metric),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        interpret=interpret,
    )(raw_ids, Q, table)
    return jnp.where(ids >= 0, out, jnp.inf)
