"""End-to-end RAG serving: WebANNS retrieval feeding a smoke-scale LM,
with the retrieval/KV HBM budget split by the cache-size optimizer.

    PYTHONPATH=src python examples/rag_serving.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.engine import EngineConfig, WebANNSEngine
from repro.data.synthetic import corpus_embeddings, corpus_texts
from repro.models import transformer as T
from repro.serve.rag import RAGPipeline, budget_retrieval
from repro.serve.serve_loop import greedy_generate


def main():
    # corpus + index (offline)
    X = corpus_embeddings(700, 48, seed=3)
    texts = corpus_texts(700, seed=3)
    engine = WebANNSEngine.build(
        X, M=8, ef_construction=50, texts=texts,
        config=EngineConfig(cache_capacity=len(X)),
    )

    # split a (toy) HBM budget between ANNS cache and KV cache
    probes = X[:4] + 0.02
    cache_items, kv_budget = budget_retrieval(
        engine, probes, hbm_budget_bytes=len(X) * 48 * 4, p=0.8,
        t_theta=0.05,
    )
    print(f"HBM split: ANNS cache {cache_items} items, "
          f"KV budget {kv_budget/1e3:.0f} KB")

    # generator: smoke-config qwen (any LM arch works via --arch)
    cfg = configs.get("qwen2.5-14b").make_smoke_config()
    params = T.init_lm(jax.random.PRNGKey(0), cfg)

    def embed(query: str) -> np.ndarray:
        rng = np.random.default_rng(abs(hash(query)) % 2**31)
        return X[rng.integers(0, len(X))] + 0.03

    def tokenize(query: str, docs) -> np.ndarray:
        rng = np.random.default_rng(abs(hash(query)) % 2**31)
        return rng.integers(0, cfg.vocab, (1, 8)).astype(np.int32)

    def generate(prompt: np.ndarray) -> np.ndarray:
        return np.asarray(
            greedy_generate(params, cfg, jnp.asarray(prompt), n_new=8)
        )

    rag = RAGPipeline(engine, embed, tokenize, generate, k=3)
    for query in ("what is attention", "expert routing", "hnsw layers"):
        out = rag(query)
        s = out.retrieval_stats
        print(f"Q: {query!r}")
        print(f"  retrieved {out.retrieved_ids.tolist()} "
              f"(n_db={s.n_db}, |Q|={s.n_visited})")
        print(f"  generated tokens: {out.generated[0, -8:].tolist()}")


if __name__ == "__main__":
    main()
