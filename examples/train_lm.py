"""End-to-end driver: train a ~100M-param dense LM for a few hundred
steps with the full substrate (AdamW, microbatching, async checkpoints,
straggler monitor, gradient compression).

    PYTHONPATH=src python examples/train_lm.py --steps 300 --d-model 256

The default config (--steps 30) keeps CI-speed; --steps 300 with the
defaults below is the ~100M-param run.
"""

import argparse
import time

import jax
import numpy as np

from repro.data.pipeline import PrefetchPipeline
from repro.data.synthetic import token_batches
from repro.models.transformer import LMConfig, init_lm, lm_loss
from repro.train.checkpoint import AsyncCheckpointer
from repro.train.compression import CompressionConfig, init_ef_state
from repro.train.elastic import StragglerMonitor
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt", default="reports/ckpt_example")
    args = ap.parse_args()

    cfg = LMConfig(
        n_layers=args.layers, d_model=args.d_model,
        n_heads=max(2, args.d_model // 64), kv_heads=max(1, args.d_model // 128),
        d_ff=args.d_model * 4, vocab=args.vocab,
    )
    print(f"params: {cfg.param_count()/1e6:.1f}M")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    comp = CompressionConfig() if args.compress else None
    ef = init_ef_state(params) if args.compress else None
    step = make_train_step(
        lambda p, b: lm_loss(p, b["tokens"], b["labels"], cfg,
                             loss_chunk=min(args.seq, 128)),
        AdamWConfig(lr=3e-4, warmup_steps=20),
        microbatches=args.microbatches,
        compression=comp,
        donate=False,
    )
    ckpt = AsyncCheckpointer(args.ckpt, keep=2)
    mon = StragglerMonitor(factor=4.0)
    pipe = PrefetchPipeline(
        token_batches(cfg.vocab, args.batch, args.seq, args.steps), depth=2
    )
    t0 = time.time()
    loss0 = None
    for i, batch in enumerate(pipe):
        mon.start_step()
        params, opt, ef, m = step(params, opt, ef, batch)
        mon.end_step(i)
        if loss0 is None:
            loss0 = m["loss"]
        if i % 10 == 0:
            print(f"step {i:4d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.2f}")
        if (i + 1) % 50 == 0:
            ckpt.save(i + 1, {"params": params})
    ckpt.wait()
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"done: loss {float(loss0):.3f} → {float(m['loss']):.3f} "
          f"in {dt:.0f}s ({toks/dt:.0f} tok/s); "
          f"stragglers flagged: {len(mon.events)}")


if __name__ == "__main__":
    main()
