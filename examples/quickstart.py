"""Quickstart: build a WebANNS index, query it through the tiered store,
persist it, reopen it from disk shards, optimize the cache size with
Algorithm 2, and verify recall.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import numpy as np

from repro.core.cache_opt import QueryTestStats, optimize_memory_size
from repro.core.engine import EngineConfig, SearchRequest, WebANNSEngine
from repro.core.hnsw import exact_search
from repro.data.synthetic import corpus_embeddings, corpus_texts


def main():
    # 1. a personalized corpus: 1200 docs, 64-d embeddings (+ texts,
    #    stored separately — the paper's text-embedding separation)
    X = corpus_embeddings(1200, 64, seed=0)
    texts = corpus_texts(1200, seed=0)

    # 2. offline index construction (the service-worker stage)
    print("building HNSW index…")
    eng = WebANNSEngine.build(
        X, M=10, ef_construction=60, texts=texts,
        config=EngineConfig(mode="webanns", cache_capacity=len(X) // 4),
    )

    # 3. online queries through the three-tier store with lazy loading
    rng = np.random.default_rng(1)
    q = X[42] + 0.05 * rng.standard_normal(64).astype(np.float32)
    res = eng.search(SearchRequest(query=q, k=5, ef=64))
    ids, stats = res.ids, res.stats
    print(f"top-5 ids: {ids.tolist()}")
    print(f"  visited |Q|={stats.n_visited}, external accesses "
          f"n_db={stats.n_db}, items fetched={stats.items_fetched}")
    print(f"  first hit text: {eng.get_texts(ids[:1])[0][:60]}…")
    ex, _ = exact_search(X, q, 5)
    print(f"  recall@5 vs brute force: "
          f"{len(set(ids.tolist()) & set(ex.tolist()))}/5")

    # 4. persistence lifecycle: save → reopen from disk shards → query.
    #    The reopened session serves tier-3 fetches from mmap-backed
    #    .npy shards (no HNSW rebuild) and returns identical results.
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "index")
        eng.save(path)
        reopened = WebANNSEngine.open(
            path, config=EngineConfig(cache_capacity=len(X) // 4))
        res2 = reopened.search(SearchRequest(query=q, k=5, ef=64))
        assert np.array_equal(res.ids, res2.ids)
        assert np.array_equal(res.dists, res2.dists)
        backend = reopened.external.base_backend
        print(f"saved → reopened from {len(os.listdir(path))} files; "
              f"identical top-5; tier-3 served from disk "
              f"(n_db={reopened.external.stats.n_db}, "
              f"shard_reads={backend.shard_reads})")

    # 5. heuristic cache-size optimization (Algorithm 2, p=0.8, Tθ=100ms)
    probes = X[rng.choice(len(X), 4)] + 0.05
    def query_test(c):
        eng.resize_cache(c)
        eng.warm_cache()
        agg = [eng.search(SearchRequest(query=p, k=5, ef=64)).stats
               for p in probes]
        return QueryTestStats(
            n_db=float(np.mean([s.n_db for s in agg])),
            n_q=float(np.mean([s.n_visited for s in agg])),
            t_query=float(np.mean([s.t_query for s in agg])),
            t_db=eng.external.access_cost(64),
        )

    res = optimize_memory_size(query_test, c0=len(X), p=0.8, t_theta=0.1,
                               max_iters=6)
    print(f"cache optimizer: {res.c0} → {res.c_best} items "
          f"({res.saved_fraction()*100:.0f}% memory saved, "
          f"{len(res.steps)} probes)")

    # 6. precision mode (DESIGN.md §7): an int8 tier-2 cache holds ~4x
    #    the vectors per byte; the exact-rerank pass keeps recall at
    #    parity with float32 — asserted here (the CI smoke contract).
    eng32 = WebANNSEngine(X, eng.graph,
                          EngineConfig(cache_capacity=len(X) // 4))
    eng8 = WebANNSEngine(X, eng.graph, EngineConfig(
        cache_capacity=len(X) // 4, precision="int8"))
    ex10, _ = exact_search(X, q, 10)
    r32 = eng32.search(SearchRequest(query=q, k=10, ef=64))
    r8 = eng8.search(SearchRequest(query=q, k=10, ef=64))
    rec32 = len(set(r32.ids.tolist()) & set(ex10.tolist())) / 10
    rec8 = len(set(r8.ids.tolist()) & set(ex10.tolist())) / 10
    assert rec8 >= 0.95 * rec32, (rec8, rec32)
    print(f"int8 precision: cache {eng32.cache_bytes()} → "
          f"{eng8.cache_bytes()} bytes at equal capacity; "
          f"recall@10 {rec8:.2f} vs float32 {rec32:.2f} (parity OK)")

    # 7. mutation lifecycle (DESIGN.md §8): the corpus is alive —
    #    build → add → delete → save (delta) → reopen, no rebuild ever.
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "live_index")
        live = WebANNSEngine.build(
            X[:1000], M=10, ef_construction=60,
            config=EngineConfig(cache_capacity=250))
        info = live.save(path)
        added = live.add(X[1000:], texts=texts[1000:])  # incremental insert
        hit = live.search(SearchRequest(query=X[1100], k=1, ef=32))
        assert hit.ids[0] in added.ids  # new docs retrievable immediately
        gone = live.delete(added.ids[:10]).deleted  # GDPR-style forget
        res = live.search(SearchRequest(query=q, k=5, ef=64))
        assert not set(gone.tolist()) & set(res.ids.tolist())
        info = live.save(path)  # writes ONLY deltas + tombstones
        assert info["mode"] == "delta"
        reopened = WebANNSEngine.open(
            path, config=EngineConfig(cache_capacity=250))
        res2 = reopened.search(SearchRequest(query=q, k=5, ef=64))
        assert np.array_equal(res.ids, res2.ids)  # replay is exact
        assert np.array_equal(res.dists, res2.dists)
        print(f"mutation lifecycle: +{len(added.ids)} docs / "
              f"-{len(gone)} tombstones → delta save "
              f"{info['bytes_written']} bytes (epoch {info['epoch']}); "
              f"reopened engine bit-identical (n_live={reopened.n_live})")


if __name__ == "__main__":
    main()
