"""Recsys candidate retrieval: brute-force scoring vs WebANNS HNSW.

The ``retrieval_cand`` shape (1 query × 1M candidates) is exactly the
ANNS serving problem. This example scores a user query against a candidate
catalog both ways and compares results + work done.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineConfig, SearchRequest, WebANNSEngine
from repro.data.synthetic import corpus_embeddings
from repro.models.recsys import retrieval_score


def main():
    n_cand, dim, k = 6_000, 32, 10
    cands = corpus_embeddings(n_cand, dim, seed=7)
    user = cands[123] + 0.05  # a user vector near a real item

    # 1) brute force (the serve_bulk path; Pallas scan kernels on TPU)
    t0 = time.perf_counter()
    d_bf, i_bf = retrieval_score(jnp.asarray(user)[None], jnp.asarray(cands),
                                 k=k)
    i_bf = np.asarray(i_bf)[0]
    t_bf = time.perf_counter() - t0
    print(f"brute force: top-{k} in {t_bf*1e3:.1f} ms "
          f"(scored {n_cand} candidates)")

    # 2) WebANNS index (ip metric == dot-product scoring)
    print("building catalog index…")
    eng = WebANNSEngine.build(
        cands, M=10, ef_construction=60,
        config=EngineConfig(metric="ip", cache_capacity=n_cand // 5),
    )
    req = SearchRequest(query=user, k=k, ef=96)
    eng.search(req)  # warm-up (compile; paper protocol)
    t0 = time.perf_counter()
    res = eng.search(req)
    ids, stats = res.ids, res.stats
    t_ann = time.perf_counter() - t0
    overlap = len(set(ids.tolist()) & set(i_bf.tolist()))
    print(f"webanns: top-{k} in {t_ann*1e3:.1f} ms — visited only "
          f"|Q|={stats.n_visited}/{n_cand} candidates "
          f"({stats.n_db} external accesses)")
    print(f"recall vs brute force: {overlap}/{k}")


if __name__ == "__main__":
    main()
